"""FedChain distributed-runtime semantics on CPU (single device where
possible; shard_map grouped collectives via subprocess for device isolation)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.launch import fedchain as fc
from repro.models import model_zoo, transformer
from repro.optim import sgd

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_broadcast_and_sync_roundtrip():
    cfg = registry.get_config("mamba2-1.3b", smoke=True)
    params = transformer.init_model(cfg, jax.random.PRNGKey(0))
    stacked = fc.broadcast_to_clients(params, 3)
    sync = fc.make_sync_step(3)
    merged = sync(stacked)
    for a, b in zip(jax.tree.leaves(stacked), jax.tree.leaves(merged)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-5)


def test_local_round_clients_diverge_then_merge():
    """Different client data ⇒ replicas diverge during the round; the round
    boundary re-merges them to a common model (FedAvg semantics)."""
    import dataclasses

    from repro.configs import INPUT_SHAPES

    cfg = registry.get_config("qwen3-14b", smoke=True)
    shape = dataclasses.replace(INPUT_SHAPES["train_4k"], seq_len=32, global_batch=2)
    params = transformer.init_model(cfg, jax.random.PRNGKey(0))
    opt = sgd(0.2)
    c, steps = 2, 3
    fl = fc.FedChainConfig(local_steps=steps)
    local_only = fc.make_local_steps_only(cfg, opt, fl)
    client_p = fc.broadcast_to_clients(params, c)
    client_o = jax.vmap(opt.init)(client_p)
    batches = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1),
                                     (steps, c, 2, 32), 0, cfg.vocab_size)}
    new_p, _, losses = local_only(client_p, client_o, batches)
    # diverged: client 0 and 1 params differ somewhere
    diverged = any(
        float(jnp.max(jnp.abs(l[0].astype(jnp.float32) - l[1].astype(jnp.float32)))) > 1e-6
        for l in jax.tree.leaves(new_p))
    assert diverged
    merged = fc.make_sync_step(c)(new_p)
    for l in jax.tree.leaves(merged):
        np.testing.assert_allclose(np.asarray(l[0], np.float32),
                                   np.asarray(l[1], np.float32), rtol=1e-6)


def test_selection_step_picks_lower_loss():
    import dataclasses

    from repro.configs import INPUT_SHAPES

    cfg = registry.get_config("gemma3-4b", smoke=True)
    shape = dataclasses.replace(INPUT_SHAPES["train_4k"], seq_len=32, global_batch=2)
    params = transformer.init_model(cfg, jax.random.PRNGKey(0))
    # candidate B: slightly trained => lower loss
    batch = model_zoo.concrete_batch(cfg, shape, jax.random.PRNGKey(1))
    opt = sgd(0.3)
    step = jax.jit(model_zoo.make_train_step(cfg, opt))
    trained, s, _ = step(params, opt.init(params), batch)
    for _ in range(3):
        trained, s, _ = step(trained, s, batch)
    c = 2
    ca = fc.broadcast_to_clients(params, c)
    cb = fc.broadcast_to_clients(trained, c)
    probe = jax.tree.map(lambda t: jnp.stack([t, t]), batch)
    select = fc.make_selection_step(cfg)
    chosen, picked_a, (la, lb) = select(ca, cb, probe)
    assert float(lb) < float(la)
    assert not bool(picked_a)
    for l1, l2 in zip(jax.tree.leaves(chosen), jax.tree.leaves(cb)):
        np.testing.assert_allclose(np.asarray(l1, np.float32),
                                   np.asarray(l2, np.float32))


@pytest.mark.slow
def test_shardmap_grouped_fedavg_matches_reference():
    """Grouped-psum FedAvg round (shard_map + axis_index_groups) == the
    reference per-group computation, on 8 fake devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.dist import compat
        from repro.launch.fedchain_shardmap import run_grouped_fedavg_round, client_groups

        mesh = compat.make_mesh((4, 2), ("data", "model"))
        # toy quadratic "model": params [d]; loss per batch row ||x - p||^2
        def loss_fn(p, batch):
            return jnp.mean(jnp.sum((batch - p[None, :]) ** 2, -1))

        d, steps, clients, lr = 8, 3, 2, 0.1
        params = jnp.zeros((d,))
        batches = jax.random.normal(jax.random.PRNGKey(0), (steps, 8, d))

        merged, loss = run_grouped_fedavg_round(
            loss_fn, params, batches, mesh=mesh, clients=clients, lr=lr, steps=steps)

        # reference: run each client group separately on its data half
        def client_run(p, bs):
            for t in range(steps):
                g = jax.grad(loss_fn)(p, bs[t])
                p = p - lr * g
            return p
        half = batches.shape[1] // clients
        ps = [client_run(params, batches[:, i*half:(i+1)*half]) for i in range(clients)]
        ref = sum(ps) / clients
        err = float(jnp.max(jnp.abs(merged - ref)))
        print(json.dumps({"err": err}))
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["err"] < 1e-5
