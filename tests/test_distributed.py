"""Distribution integration tests (subprocess isolation: these need multiple
fake XLA host devices, which must not leak into the other tests)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


@pytest.mark.slow
def test_dryrun_lowers_on_debug_mesh():
    """A small-mesh version of deliverable (e): lower+compile succeeds and
    emits collectives for a sharded train step."""
    out = _run("""
        import json
        from repro.launch.dryrun import lower_one
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh(data=2, model=4)
        rec = lower_one('gemma3-4b', 'train_4k', mesh, 'debug', measure_depth=False)
        assert rec['status'] == 'ok', rec
        colls = rec['roofline']['collectives']
        assert colls['all-reduce']['count'] > 0  # gradient sync exists
        print(json.dumps({'ok': True, 'dom': rec['roofline']['dominant']}))
    """)
    assert json.loads(out.strip().splitlines()[-1])["ok"]


@pytest.mark.slow
def test_fedchain_local_phase_has_no_cross_client_collectives():
    """THE paper-mapping invariant: the A_local phase program must not
    communicate across the client axis; the sync step must."""
    out = _run("""
        import json
        from repro.launch.dryrun import lower_fedchain
        from repro.launch.fedchain import make_fl_mesh
        mesh = make_fl_mesh(clients=2, data=2, model=2)
        rec = lower_fedchain('gemma3-4b', mesh, 'fl_debug')
        local = rec['phases']['local_phase']['collectives']
        sync = rec['phases']['sync_step']['collectives']
        glob = rec['phases']['global_step']['collectives']
        # local phase collectives are within-client only; the parameter-average
        # sync step is where cross-client bytes live.
        assert sync['all-reduce']['bytes'] + sync['all-gather']['bytes'] > 0
        n_local = sum(v['bytes'] for v in local.values())
        n_global = sum(v['bytes'] for v in glob.values())
        print(json.dumps({'local': n_local, 'sync_ok': True, 'global': n_global}))
    """, devices=8)
    rec = json.loads(out.strip().splitlines()[-1])
    assert rec["sync_ok"]


@pytest.mark.slow
def test_multipod_mesh_builds_with_512_devices():
    """make_production_mesh(multi_pod=True) shards the pod axis (Lemma:
    deliverable e's 512-chip requirement, host-device backed)."""
    out = _run("""
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh(multi_pod=False)
        m2 = make_production_mesh(multi_pod=True)
        assert m1.devices.shape == (16, 16)
        assert m2.devices.shape == (2, 16, 16)
        assert m2.axis_names == ('pod', 'data', 'model')
        print('ok')
    """, devices=512)
    assert "ok" in out


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """Numerical equivalence: the pjit-sharded train step == unsharded."""
    out = _run("""
        import dataclasses, json
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import registry, INPUT_SHAPES
        from repro.launch.mesh import make_debug_mesh
        from repro.models import model_zoo, transformer
        from repro.optim import sgd
        from repro.sharding import RuleSet, param_specs, use_rules

        cfg = registry.get_config('qwen3-14b', smoke=True)
        shape = dataclasses.replace(INPUT_SHAPES['train_4k'], seq_len=64, global_batch=4)
        key = jax.random.PRNGKey(0)
        params = transformer.init_model(cfg, key)
        batch = model_zoo.concrete_batch(cfg, shape, key)
        opt = sgd(0.1)
        step = model_zoo.make_train_step(cfg, opt)
        p1, _, m1 = jax.jit(step)(params, (), batch)

        mesh = make_debug_mesh(data=2, model=4)
        rs = RuleSet(mesh)
        p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                            param_specs(params, rs),
                            is_leaf=lambda s: isinstance(s, P))
        with use_rules(rs):
            jstep = jax.jit(step, in_shardings=(p_sh, (), None),
                            out_shardings=(p_sh, (), None))
            p2, _, m2 = jstep(params, (), batch)
        d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        print(json.dumps({'loss1': float(m1['loss']), 'loss2': float(m2['loss']),
                          'max_param_diff': d}))
    """)
    rec = json.loads(out.strip().splitlines()[-1])
    assert abs(rec["loss1"] - rec["loss2"]) < 1e-3
    assert rec["max_param_diff"] < 1e-2
