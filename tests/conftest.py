import os

# Tests and benches must see ONE device (the dry-run sets 512 only inside
# repro.launch.dryrun, never globally). Keep CPU determinism.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
