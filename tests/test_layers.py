"""Layer-level unit tests: masks, RoPE, MoE routing invariants, SSD vs naive
recurrence, chunked attention equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttentionConfig, MoEConfig, SSMConfig
from repro.models.layers import attention as attn_lib
from repro.models.layers import common, moe as moe_lib, ssm as ssm_lib


# --------------------------- attention -------------------------------------

def test_causal_mask_brute_force():
    qp = jnp.arange(6)
    bias = attn_lib.mask_bias(qp, qp, causal=True)
    for i in range(6):
        for j in range(6):
            assert (bias[i, j] == 0) == (j <= i)


def test_sliding_window_mask():
    qp = jnp.arange(8)
    bias = attn_lib.mask_bias(qp, qp, causal=True, window=jnp.asarray(3))
    for i in range(8):
        for j in range(8):
            ok = (j <= i) and (i - j < 3)
            assert (bias[i, j] == 0) == ok


def test_prefix_lm_mask():
    qp = jnp.arange(6)
    bias = attn_lib.mask_bias(qp, qp, causal=True, prefix_len=3)
    # prefix is bidirectional
    assert bias[0, 2] == 0 and bias[2, 0] == 0
    # text stays causal
    assert bias[3, 4] < 0 and bias[4, 3] == 0


def test_chunked_attention_equals_full():
    key = jax.random.PRNGKey(0)
    b, s, h, kv, d = 2, 512, 4, 2, 16
    q = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, d))
    pos = jnp.arange(s)
    bias = attn_lib.mask_bias(pos, pos, causal=True)
    full = attn_lib.attend(q, k, v, bias[None], scale=0.25)

    def bias_fn(start):
        qp = jax.lax.dynamic_slice_in_dim(pos, start, 128)
        return attn_lib.mask_bias(qp, pos, causal=True)

    chunked = attn_lib.attend_chunked(q, k, v, scale=0.25, bias_fn=bias_fn,
                                      q_block=128)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               rtol=2e-4, atol=2e-5)


def test_rope_relative_property():
    """RoPE: ⟨rope(q,m), rope(k,n)⟩ depends only on m − n."""
    d = 32
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, d))

    def score(m, n):
        qm = common.rope(q, jnp.asarray([[m]]), 10_000.0)
        kn = common.rope(k, jnp.asarray([[n]]), 10_000.0)
        return float(jnp.vdot(qm, kn))

    assert score(3, 1) == pytest.approx(score(10, 8), rel=1e-4)
    assert score(5, 5) == pytest.approx(score(0, 0), rel=1e-4)


def test_gqa_head_grouping():
    """GQA with kv replicated == MHA where kv heads are tiled."""
    b, s, h, d = 1, 8, 4, 8
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, d))
    k2 = jax.random.normal(jax.random.PRNGKey(1), (b, s, 2, d))
    v2 = jax.random.normal(jax.random.PRNGKey(2), (b, s, 2, d))
    pos = jnp.arange(s)
    bias = attn_lib.mask_bias(pos, pos, causal=True)[None]
    out_gqa = attn_lib.attend(q, k2, v2, bias, scale=1.0)
    k4 = jnp.repeat(k2, 2, axis=2)
    v4 = jnp.repeat(v2, 2, axis=2)
    out_mha = attn_lib.attend(q, k4, v4, bias, scale=1.0)
    np.testing.assert_allclose(np.asarray(out_gqa), np.asarray(out_mha), rtol=1e-5)


# --------------------------- MoE --------------------------------------------

MCFG = MoEConfig(num_experts=4, top_k=2, d_expert=16, capacity_factor=2.0)


def _moe_setup(t=32, d=8):
    params = moe_lib.init_moe(jax.random.PRNGKey(0), d, MCFG, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, t // 2, d))
    return params, x


def test_moe_output_shape_and_aux():
    params, x = _moe_setup()
    out, aux = moe_lib.moe_apply(params, x, mcfg=MCFG)
    assert out.shape == x.shape
    assert float(aux) >= 0.0


def test_moe_aux_loss_balanced_floor():
    """Switch aux: E·Σ f_e p_e ≥ 1 (×weight), == 1 at perfect balance."""
    params, x = _moe_setup(t=256)
    _, aux = moe_lib.moe_apply(params, x, mcfg=MCFG)
    # f sums to top_k (each token lands on top_k experts)
    assert float(aux) >= MCFG.aux_loss_weight * MCFG.top_k * 0.98


def test_moe_capacity_drop():
    """cf→tiny forces drops ⇒ output norm shrinks but stays finite."""
    params, x = _moe_setup(t=64)
    small = dataclasses.replace(MCFG, capacity_factor=0.25)
    out_small, _ = moe_lib.moe_apply(params, x, mcfg=small)
    out_big, _ = moe_lib.moe_apply(params, x, mcfg=MCFG)
    assert bool(jnp.isfinite(out_small).all())
    assert float(jnp.linalg.norm(out_small)) <= float(jnp.linalg.norm(out_big)) + 1e-3


def test_moe_group_locality():
    """routing_groups=2 == independently routing each half of the batch."""
    params, x = _moe_setup(t=64)
    out2, _ = moe_lib.moe_apply(params, x, mcfg=MCFG, routing_groups=2)
    # groups = flattened halves of [B*S]; with B=2 the halves are the batch rows
    oa, _ = moe_lib.moe_apply(params, x[:1], mcfg=MCFG)
    ob, _ = moe_lib.moe_apply(params, x[1:], mcfg=MCFG)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(jnp.concatenate([oa, ob])),
                               rtol=1e-4, atol=1e-5)


def test_moe_shared_expert_and_dense_residual():
    d = 8
    cfg = dataclasses.replace(MCFG, num_shared_experts=1, dense_residual_d_ff=16)
    params = moe_lib.init_moe(jax.random.PRNGKey(0), d, cfg, jnp.float32)
    assert "shared" in params and "dense_residual" in params
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, d))
    out, _ = moe_lib.moe_apply(params, x, mcfg=cfg)
    assert bool(jnp.isfinite(out).all())


# --------------------------- SSD / Mamba2 -----------------------------------

def _naive_ssm(x, dt, a_coef, b_in, c_in):
    """Reference: plain sequential recurrence h_t = e^{dtA}h + dt·B⊗x."""
    bsz, l, h, p = x.shape
    n = b_in.shape[-1]
    rep = h // b_in.shape[2]
    bh = jnp.repeat(b_in, rep, axis=2)
    ch = jnp.repeat(c_in, rep, axis=2)
    state = jnp.zeros((bsz, h, p, n))
    ys = []
    for t in range(l):
        da = jnp.exp(dt[:, t] * a_coef[None])  # [B,H]
        upd = jnp.einsum("bh,bhn,bhp->bhpn", dt[:, t], bh[:, t], x[:, t])
        state = state * da[:, :, None, None] + upd
        ys.append(jnp.einsum("bhpn,bhn->bhp", state, ch[:, t]))
    return jnp.stack(ys, axis=1), state


def test_ssd_matches_naive_recurrence():
    bsz, l, h, p, g, n = 2, 32, 4, 8, 1, 8
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (bsz, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (bsz, l, h)))
    a_coef = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (h,)) * 0.3)
    b_in = jax.random.normal(jax.random.PRNGKey(3), (bsz, l, g, n)) * 0.5
    c_in = jax.random.normal(jax.random.PRNGKey(4), (bsz, l, g, n)) * 0.5
    y_ssd, st_ssd = ssm_lib.ssd(x, dt, a_coef, b_in, c_in, chunk=8)
    y_ref, st_ref = _naive_ssm(x, dt, a_coef, b_in, c_in)
    np.testing.assert_allclose(np.asarray(y_ssd), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st_ssd), np.asarray(st_ref),
                               rtol=2e-3, atol=2e-3)


def test_ssd_decode_step_continues_prefill():
    bsz, l, h, p, g, n = 1, 16, 2, 4, 1, 4
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (bsz, l + 1, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(6), (bsz, l + 1, h)))
    a_coef = -jnp.exp(jnp.zeros((h,)))
    b_in = jax.random.normal(jax.random.PRNGKey(7), (bsz, l + 1, g, n)) * 0.5
    c_in = jax.random.normal(jax.random.PRNGKey(8), (bsz, l + 1, g, n)) * 0.5
    y_full, _ = ssm_lib.ssd(x[:, :l + 1][:, :16], dt[:, :16], a_coef,
                            b_in[:, :16], c_in[:, :16], chunk=8)
    # prefill l tokens then decode token l... use l=16 path for full; compare
    y_pre, st = ssm_lib.ssd(x[:, :l], dt[:, :l], a_coef, b_in[:, :l],
                            c_in[:, :l], chunk=8)
    y_t, _ = ssm_lib.ssd_decode_step(
        st, x[:, l].reshape(bsz, h, p), dt[:, l], a_coef,
        b_in[:, l], c_in[:, l])
    # decode at t=16 should equal running ssd over 17 with last step... use naive
    y_ref, _ = _naive_ssm(x, dt, a_coef, b_in, c_in)
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_ref[:, l]),
                               rtol=2e-3, atol=2e-3)


def test_causal_conv_state_consistency():
    """Streaming conv with carried state == full conv."""
    b, l, c = 1, 12, 6
    x = jax.random.normal(jax.random.PRNGKey(0), (b, l, c))
    w = jax.random.normal(jax.random.PRNGKey(1), (4, c)) * 0.5
    bias = jnp.zeros((c,))
    full, _ = ssm_lib._causal_conv(x, w, bias)
    part1, st = ssm_lib._causal_conv(x[:, :8], w, bias)
    part2, _ = ssm_lib._causal_conv(x[:, 8:], w, bias, state=st)
    np.testing.assert_allclose(np.asarray(full[:, 8:]), np.asarray(part2),
                               rtol=1e-5, atol=1e-6)


def test_cross_entropy_matches_naive():
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 11))
    targets = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0, 11)
    got = common.cross_entropy(logits, targets)
    probs = jax.nn.log_softmax(logits, -1)
    want = -jnp.mean(jnp.take_along_axis(probs, targets[..., None], -1))
    assert float(jnp.abs(got - want)) < 1e-5
