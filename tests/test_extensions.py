"""Tests for the beyond-baseline subsystems: gradient accumulation, metrics
logging, the FSDP/fallback sharding options, and the decay-schedule runner
variants."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import INPUT_SHAPES, registry
from repro.models import model_zoo, transformer
from repro.optim import sgd
from repro.optim.accumulate import make_accumulating_train_step

SHAPE = dataclasses.replace(INPUT_SHAPES["train_4k"], seq_len=64, global_batch=4)


def test_grad_accumulation_matches_full_batch():
    """N microbatches with mean-accumulated grads == one full-batch step."""
    cfg = registry.get_config("qwen3-14b", smoke=True)
    key = jax.random.PRNGKey(0)
    params = transformer.init_model(cfg, key)
    batch = model_zoo.concrete_batch(cfg, SHAPE, key)
    opt = sgd(0.1)

    def loss_fn(p, b):
        return transformer.lm_loss(p, cfg, b)

    full = jax.jit(model_zoo.make_train_step(cfg, opt))
    acc = jax.jit(make_accumulating_train_step(loss_fn, opt, microbatches=4))
    p1, _, m1 = full(params, opt.init(params), batch)
    p2, _, m2 = acc(params, opt.init(params), batch)
    # losses are mean-per-token over different partitions — close, not equal
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 0.05
    diffs = [float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2))]
    assert max(diffs) < 5e-3


def test_metrics_logger_roundtrip(tmp_path):
    from repro.launch.metrics import MetricsLogger, read_jsonl

    path = str(tmp_path / "m.jsonl")
    lg = MetricsLogger(path, window=3)
    for i in range(5):
        lg.log(i, loss=float(i))
    lg.close()
    recs = read_jsonl(path)
    assert len(recs) == 5 and recs[3]["loss"] == 3.0
    assert lg.mean("loss") == pytest.approx((2 + 3 + 4) / 3)


def test_fsdp_spec_shards_big_weights():
    from repro.sharding import RuleSet, param_specs

    from repro.dist import compat

    mesh = compat.abstract_mesh((2, 2), ("data", "model"))
    rs = RuleSet(mesh, fsdp=True)
    shapes = {
        "seg0": {"mlp": {"w_in": jax.ShapeDtypeStruct((2, 1024, 1024), jnp.float32)}},
        "tiny": {"bias": jax.ShapeDtypeStruct((8,), jnp.float32)},
    }
    specs = param_specs(shapes, rs)
    w_spec = specs["seg0"]["mlp"]["w_in"]
    assert "data" in [s for s in w_spec if s is not None]  # big leaf sharded
    assert all(s is None for s in specs["tiny"]["bias"])  # small leaf untouched


def test_attn_fallback_spec():
    from repro.sharding import RuleSet, param_specs

    from repro.dist import compat

    mesh = compat.abstract_mesh((1, 4), ("data", "model"))
    shapes = {"attn": {"wq": jax.ShapeDtypeStruct((64, 6, 16), jnp.float32)}}
    # 6 heads % 4 != 0: default replicates, fallback shards embed(64)
    plain = param_specs(shapes, RuleSet(mesh))["attn"]["wq"]
    assert all(s is None for s in plain)
    fb = param_specs(shapes, RuleSet(mesh, attn_embed_fallback=True))["attn"]["wq"]
    assert fb[0] == "model"


def test_train_driver_with_microbatches():
    from repro.launch import train as train_lib

    res = train_lib.main([
        "--arch", "mamba2-1.3b", "--smoke", "--steps", "6", "--batch", "4",
        "--seq", "64", "--lr", "0.3", "--microbatches", "2",
        "--log-every", "100"])
    assert res["final_loss"] < res["first_loss"] * 1.2  # trains, no blow-up


def test_train_driver_writes_metrics(tmp_path):
    from repro.launch import train as train_lib
    from repro.launch.metrics import read_jsonl

    path = str(tmp_path / "run.jsonl")
    train_lib.main([
        "--arch", "gemma3-4b", "--smoke", "--steps", "4", "--batch", "2",
        "--seq", "32", "--metrics-path", path, "--log-every", "100"])
    recs = read_jsonl(path)
    assert len(recs) == 4 and "loss" in recs[0]


def test_checkpointed_training_resume(tmp_path):
    """Save at step k, restore, continue: states match a straight run."""
    from repro.checkpoint import restore, save_checkpoint

    cfg = registry.get_config("qwen3-14b", smoke=True)
    key = jax.random.PRNGKey(0)
    params = transformer.init_model(cfg, key)
    batch = model_zoo.concrete_batch(cfg, SHAPE, key)
    opt = sgd(0.1)
    step = jax.jit(model_zoo.make_train_step(cfg, opt))

    p, s = params, opt.init(params)
    for _ in range(3):
        p, s, _ = step(p, s, batch)
    d = str(tmp_path / "ck")
    save_checkpoint(d, 3, p)
    p_restored = restore(d, 3, p)
    p1, _, _ = step(p, s, batch)
    p2, _, _ = step(p_restored, s, batch)
    diffs = [float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2))]
    assert max(diffs) < 1e-5
