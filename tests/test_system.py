"""End-to-end behaviour tests: the training/serving drivers and the FedChain
feature produce working runs on CPU (smoke scale)."""
import jax.numpy as jnp
import pytest

from repro.launch import serve as serve_lib
from repro.launch import train as train_lib


def test_train_plain_loss_drops():
    res = train_lib.main([
        "--arch", "qwen3-14b", "--smoke", "--steps", "25", "--batch", "4",
        "--seq", "64", "--lr", "0.3", "--log-every", "100"])
    assert res["final_loss"] < res["first_loss"]


def test_train_fedchain_end_to_end():
    """The full Algo-1 pipeline: local rounds → selection → global phase."""
    res = train_lib.main([
        "--arch", "gemma3-4b", "--smoke", "--steps", "24", "--batch", "2",
        "--seq", "64", "--lr", "0.3", "--fl-mode", "fedchain", "--clients", "2",
        "--local-steps", "3", "--local-rounds", "2", "--log-every", "100"])
    assert res["final_loss"] < res["first_loss"]


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "gemma3-4b", "zamba2-1.2b"])
def test_serve_generates(arch):
    from repro.configs import registry

    cfg = registry.get_config(arch, smoke=True)
    res = serve_lib.serve(cfg, batch=2, prompt_len=32, gen=8)
    assert res["tokens"].shape == (2, 8)
    assert int(res["tokens"].min()) >= 0
    assert int(res["tokens"].max()) < cfg.vocab_size


def test_serve_encdec():
    from repro.configs import registry

    cfg = registry.get_config("seamless-m4t-medium", smoke=True)
    res = serve_lib.serve(cfg, batch=2, prompt_len=16, gen=4)
    assert res["tokens"].shape == (2, 4)


def test_serve_vlm():
    from repro.configs import registry

    cfg = registry.get_config("paligemma-3b", smoke=True)
    res = serve_lib.serve(cfg, batch=2, prompt_len=16, gen=4)
    assert res["tokens"].shape == (2, 4)
    assert bool(jnp.isfinite(res["tokens"]).all())
