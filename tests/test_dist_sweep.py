"""Distributed sweep subsystem (repro.dist).

Three layers of guarantees:

(a) partition bijection — ``dist.partition``'s pad/unpad maps the flattened
    problems × seeds cells onto shards and back with no loss, duplication
    into results, or reordering, for arbitrary grid sizes × device counts
    (hypothesis property test + deterministic sweep);
(b) bit-exactness — ``run_sweep(..., mesh=...)`` on a multi-device CPU
    debug mesh returns BITWISE the single-device results, including
    ``bits_up``/``bits_down`` under QSGD + partial participation, and each
    sharded executor traces exactly once (subprocess isolation: the fake
    XLA host devices must not leak into other tests);
(c) client axis — the psum-completed Pallas aggregation equals the
    single-device mean/aggregate to float tolerance.

The 1-device mesh cases run in-process (no XLA flag needed), so the tier-1
run exercises the sharded code path even on single-device hosts.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# ------------------------- (a) partition bijection --------------------------

def _check_partition(n_cells, n_shards):
    from repro.dist import partition

    src_idx, valid = partition.pad_cells(n_cells, n_shards)
    c_pad = partition.padded_count(n_cells, n_shards)
    assert len(src_idx) == len(valid) == c_pad
    assert c_pad % n_shards == 0 and c_pad >= n_cells
    assert c_pad - n_cells < n_shards  # minimal padding
    # identity prefix: the valid slots ARE the unpadded cells, in order —
    # composed with the prefix-slice unpad this is a bijection
    np.testing.assert_array_equal(src_idx[:n_cells], np.arange(n_cells))
    np.testing.assert_array_equal(valid, np.arange(c_pad) < n_cells)
    # padding repeats real cells only
    assert ((src_idx >= 0) & (src_idx < n_cells)).all()
    # unpad(gather(x)) == x for any per-cell payload
    payload = np.random.default_rng(0).normal(size=(n_cells, 3))
    roundtrip = partition.unpad(payload[src_idx], n_cells)
    np.testing.assert_array_equal(roundtrip, payload)


def test_partition_bijection_deterministic():
    for n_cells in (1, 2, 3, 7, 8, 12, 32, 33, 100):
        for n_shards in (1, 2, 3, 4, 7, 8, 16):
            _check_partition(n_cells, n_shards)


def test_partition_bijection_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=200, deadline=None)
    @given(n_problems=st.integers(1, 12), n_seeds=st.integers(1, 12),
           n_shards=st.integers(1, 64))
    def check(n_problems, n_seeds, n_shards):
        from repro.dist import partition

        _check_partition(n_problems * n_seeds, n_shards)
        # the flat order is p·S + s — the comm-mask fold of run_sweep
        p_idx, s_idx = partition.cell_coords(n_problems, n_seeds)
        for c in range(n_problems * n_seeds):
            assert partition.flatten_cell(p_idx[c], s_idx[c], n_seeds) == c

    check()


def test_partition_rejects_degenerate():
    from repro.dist import partition

    with pytest.raises(ValueError):
        partition.padded_count(0, 4)
    with pytest.raises(ValueError):
        partition.padded_count(4, 0)


# --------------- (b) 1-device mesh in-process (tier-1 coverage) -------------

def test_sharded_sweep_one_device_mesh_bitwise():
    """A ('grid',) mesh of ONE device runs the shard_map path end to end and
    is bitwise identical to the vmapped engine (the multi-device version of
    this assertion lives in the subprocess test below)."""
    import jax

    from repro.core import algorithms as A, sweep
    from repro.data import spec as spec_lib
    from repro.dist import make_grid_mesh

    mesh = make_grid_mesh(1)
    specs = [spec_lib.quadratic_spec(
        jax.random.PRNGKey(0), num_clients=8, dim=16, mu=0.1, beta=1.0,
        zeta=z, sigma=0.2, sigma_f=0.05) for z in (0.0, 1.0)]
    algo = A.SGD(eta=0.4, k=3, mu_avg=0.1)
    ref = sweep.run_sweep(algo, None, None, 8, seeds=(0, 1), etas=(0.3, 0.5),
                          problems=specs)
    res = sweep.run_sweep(algo, None, None, 8, seeds=(0, 1), etas=(0.3, 0.5),
                          problems=specs, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(ref.history),
                                  np.asarray(res.history))
    np.testing.assert_array_equal(np.asarray(ref.final_sub),
                                  np.asarray(res.final_sub))


def test_sharded_sweep_rejects_closure_problems():
    from repro.core import algorithms as A, sweep
    from repro.dist import make_grid_mesh

    class Legacy:  # quacks like a legacy closure problem (spec=None)
        num_clients = 4
        spec = None

    with pytest.raises(TypeError, match="spec-backed"):
        sweep.run_sweep(A.SGD(eta=0.1), Legacy(), None, 4, seeds=(0,),
                        etas=(0.1,), mesh=make_grid_mesh(1))


def test_fraction_sweep_matches_per_fraction_chain_run():
    """Satellite: the local_fraction axis rides one compile and each cell
    replays Chain.run on chain.with_local_fraction(f) (same RNG streams —
    sweep tolerance, like run_sweep vs per-call runs)."""
    import jax

    from repro.core import algorithms as A, chain, runner, sweep
    from repro.data import spec as spec_lib

    quad = spec_lib.quadratic_spec(
        jax.random.PRNGKey(0), num_clients=8, dim=16, mu=0.1, beta=1.0,
        zeta=1.0, sigma=0.2, sigma_f=0.05)
    ch = chain.fedchain(A.FedAvg(eta=0.3, local_steps=3, inner_batch=2),
                        A.SGD(eta=0.3, k=4, mu_avg=0.1), selection_k=4,
                        name="frac-eq-chain")
    fractions = (0.25, 0.5, 0.75)
    seeds = (0, 1)
    res = sweep.run_fraction_sweep(ch, quad, None, 16, seeds=seeds,
                                   fractions=fractions)
    assert res.history.shape == (2, 3, 16)
    assert np.asarray(res.selected_initial).shape == (2, 3, 1)
    for si, sd in enumerate(seeds):
        for fi, f in enumerate(fractions):
            r = ch.with_local_fraction(f).run(
                quad, quad.x0, 16, jax.random.PRNGKey(sd))
            np.testing.assert_allclose(
                np.asarray(res.history[si, fi]), np.asarray(r.history),
                rtol=2e-4, atol=1e-6)
            assert bool(res.selected_initial[si, fi, 0]) == \
                r.selected_initial[0]
    # the whole fraction grid shares ONE compile; re-running stays compiled
    with runner.assert_no_retrace(what="warm fraction grid"):
        sweep.run_fraction_sweep(ch, quad, None, 16, seeds=(2, 3),
                                 fractions=fractions)


def test_fraction_sweep_validates_inputs():
    import jax

    from repro.core import algorithms as A, chain, sweep
    from repro.data import spec as spec_lib

    quad = spec_lib.quadratic_spec(jax.random.PRNGKey(0), num_clients=4,
                                   dim=8, mu=0.1, beta=1.0)
    with pytest.raises(TypeError, match="Chain"):
        sweep.run_fraction_sweep(A.SGD(eta=0.1), quad, None, 8, seeds=(0,),
                                 fractions=(0.5,))
    three = chain.Chain(stages=[A.SGD(eta=0.1)] * 3,
                        fractions=[0.3, 0.3, 0.4], name="three")
    with pytest.raises(ValueError, match="two-stage"):
        sweep.run_fraction_sweep(three, quad, None, 8, seeds=(0,),
                                 fractions=(0.5,))
    two = chain.fedchain(A.FedAvg(eta=0.3), A.SGD(eta=0.3), name="two")
    with pytest.raises(ValueError, match="local_fraction"):
        two.with_local_fraction(1.5)
    # a fraction that starves the second stage would change the schedule
    # length (Chain.budgets clamps it back to one round) and break the
    # stacked operand layout — rejected up front with the sweepable range
    two2 = chain.fedchain(A.FedAvg(eta=0.3, local_steps=2),
                          A.SGD(eta=0.3, k=2), selection_k=2, name="two2")
    with pytest.raises(ValueError, match="sweepable fractions"):
        sweep.run_fraction_sweep(two2, quad, None, 8, seeds=(0,),
                                 fractions=(0.5, 0.9))


# ------------------ (b) multi-device subprocess bit-exactness ---------------

@pytest.mark.slow
def test_sharded_sweep_bitwise_on_debug_mesh():
    """THE dist invariant: on an 8-device CPU debug mesh, run_sweep(mesh=)
    — plain, chained, and comm'd (QSGD + partial participation + error
    feedback) — is bitwise identical to the single-device engine, bits
    accounting included, with every sharded executor traced exactly once."""
    out = _run("""
        import json
        import jax, numpy as np
        from repro.core import algorithms as A, chain, runner, sweep
        from repro.data import spec as spec_lib
        from repro.dist import make_grid_mesh
        from repro.comm import CommConfig

        assert len(jax.devices()) == 8
        mesh = make_grid_mesh()
        seeds, etas = (0, 1, 2), (0.2, 0.5)
        specs = [spec_lib.quadratic_spec(
            jax.random.PRNGKey(0), num_clients=8, dim=16, mu=0.1, beta=1.0,
            zeta=z, sigma=0.2, sigma_f=0.05) for z in (0.0, 0.5, 1.0, 2.0)]
        algo = A.SGD(eta=0.4, k=4, mu_avg=0.1)
        bw = lambda a, b: np.array_equal(np.asarray(a), np.asarray(b))
        checks = {}

        ref = sweep.run_sweep(algo, None, None, 12, seeds=seeds, etas=etas,
                              problems=specs)
        before = runner.snapshot_traces()
        res = sweep.run_sweep(algo, None, None, 12, seeds=seeds, etas=etas,
                              problems=specs, mesh=mesh)
        deltas = runner.trace_deltas(before)
        checks['algo_probs'] = (bw(ref.history, res.history)
                                and bw(ref.final_sub, res.final_sub)
                                and all(bw(a, b) for a, b in zip(
                                    jax.tree.leaves(ref.x_hat),
                                    jax.tree.leaves(res.x_hat))))
        checks['algo_single_trace'] = (deltas.get('dist-probs/sgd') == 1)
        # warm path: no re-trace
        before = runner.snapshot_traces()
        sweep.run_sweep(algo, None, None, 12, seeds=seeds, etas=etas,
                        problems=specs, mesh=mesh)
        checks['algo_warm_no_retrace'] = not runner.trace_deltas(before)

        cfg = CommConfig(compressor='qsgd', qsgd_bits=4, participation=0.5,
                         error_feedback=True)
        r = sweep.run_sweep(algo, None, None, 10, seeds=seeds, etas=etas,
                            problems=specs, comm=cfg)
        d = sweep.run_sweep(algo, None, None, 10, seeds=seeds, etas=etas,
                            problems=specs, comm=cfg, mesh=mesh)
        checks['comm'] = (bw(r.history, d.history) and bw(r.bits_up, d.bits_up)
                          and bw(r.bits_down, d.bits_down))

        ch = chain.fedchain(
            A.FedAvg(eta=0.3, local_steps=3, inner_batch=2),
            A.SGD(eta=0.3, k=4, mu_avg=0.1), selection_k=4, name='dist-ch')
        r = sweep.run_sweep(ch, None, None, 16, seeds=seeds, etas=(0.5, 1.0),
                            problems=specs)
        d = sweep.run_sweep(ch, None, None, 16, seeds=seeds, etas=(0.5, 1.0),
                            problems=specs, mesh=mesh)
        checks['chain'] = (bw(r.history, d.history)
                           and bw(r.selected_initial, d.selected_initial))

        r = sweep.run_sweep(ch, None, None, 14, seeds=seeds, etas=(1.0,),
                            problems=specs, comm=cfg)
        d = sweep.run_sweep(ch, None, None, 14, seeds=seeds, etas=(1.0,),
                            problems=specs, comm=cfg, mesh=mesh)
        checks['chain_comm'] = (bw(r.history, d.history)
                                and bw(r.bits_up, d.bits_up)
                                and bw(r.bits_down, d.bits_down))

        # no-problems path + per-cell RNG repro: cell s of the sharded grid
        # == runner.run with PRNGKey(seeds[s])-derived grid cell
        p0 = specs[2]
        r = sweep.run_sweep(algo, p0, p0.x0, 12, seeds=seeds, etas=etas)
        d = sweep.run_sweep(algo, p0, p0.x0, 12, seeds=seeds, etas=etas,
                            mesh=mesh)
        checks['noprobs'] = bw(r.history, d.history)

        print(json.dumps(checks))
    """, devices=8)
    checks = json.loads(out.strip().splitlines()[-1])
    assert all(checks.values()), checks


@pytest.mark.slow
def test_fraction_sweep_sharded_bitwise_on_debug_mesh():
    out = _run("""
        import json
        import jax, numpy as np
        from repro.core import algorithms as A, chain, runner, sweep
        from repro.data import spec as spec_lib
        from repro.dist import make_grid_mesh

        mesh = make_grid_mesh()
        quad = spec_lib.quadratic_spec(
            jax.random.PRNGKey(0), num_clients=8, dim=16, mu=0.1, beta=1.0,
            zeta=1.0, sigma=0.2, sigma_f=0.05)
        ch = chain.fedchain(
            A.FedAvg(eta=0.3, local_steps=3, inner_batch=2),
            A.SGD(eta=0.3, k=4, mu_avg=0.1), selection_k=4, name='frac-ch')
        kw = dict(seeds=(0, 1, 2), fractions=(0.2, 0.4, 0.6, 0.8))
        ref = sweep.run_fraction_sweep(ch, quad, None, 16, **kw)
        before = runner.snapshot_traces()
        res = sweep.run_fraction_sweep(ch, quad, None, 16, mesh=mesh, **kw)
        deltas = runner.trace_deltas(before)
        bw = lambda a, b: np.array_equal(np.asarray(a), np.asarray(b))
        print(json.dumps({
            'bitwise': bw(ref.history, res.history)
                       and bw(ref.final_sub, res.final_sub)
                       and bw(ref.selected_initial, res.selected_initial),
            'single_trace': deltas.get('dist-frac/frac-ch') == 1,
        }))
    """, devices=8)
    checks = json.loads(out.strip().splitlines()[-1])
    assert all(checks.values()), checks


# ------------------------------ (c) client axis -----------------------------

@pytest.mark.slow
def test_client_axis_psum_aggregation():
    """Sharded per-shard Pallas aggregation + psum == single-device mean /
    fused aggregate / full SGD round, to float tolerance."""
    out = _run("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.core import algorithms as A
        from repro.data import spec as spec_lib
        from repro.dist import client_axis
        from repro.kernels.aggregate import ops as agg_ops

        mesh = Mesh(np.asarray(jax.devices()[:4]), ('client',))
        p = spec_lib.quadratic_spec(
            jax.random.PRNGKey(0), num_clients=8, dim=16, mu=0.1, beta=1.0,
            zeta=1.0, sigma=0.2)
        rows = jax.random.normal(jax.random.PRNGKey(1), (8, 16))
        checks = {}

        ref = np.asarray(jnp.mean(rows, axis=0))
        out = np.asarray(client_axis.sharded_client_mean(mesh, rows))
        checks['mean'] = bool(np.allclose(ref, out, atol=1e-6))

        w = jax.random.uniform(jax.random.PRNGKey(2), (8,))
        ref = np.asarray(jnp.mean(w[:, None] * rows, axis=0))
        out = np.asarray(client_axis.sharded_client_mean(mesh, rows, w))
        checks['weighted_mean'] = bool(np.allclose(ref, out, atol=1e-6))

        tree = {'a': rows,
                'b': jax.random.normal(jax.random.PRNGKey(3), (8, 4, 3))}
        out_t = client_axis.sharded_client_mean(mesh, tree)
        ref_t = jax.tree.map(lambda r: jnp.mean(r, axis=0), tree)
        checks['pytree_mean'] = bool(all(
            np.allclose(np.asarray(a), np.asarray(b), atol=1e-6)
            for a, b in zip(jax.tree.leaves(ref_t), jax.tree.leaves(out_t))))

        ci = 0.1 * jax.random.normal(jax.random.PRNGKey(4), (8, 16))
        c = 0.05 * jnp.ones((16,))
        ref = np.asarray(agg_ops.chain_aggregate(p.x0, rows, ci, c, lr=0.3))
        out = np.asarray(client_axis.sharded_chain_aggregate(
            mesh, p.x0, rows, ci, c, lr=0.3))
        checks['chain_aggregate'] = bool(np.allclose(ref, out, atol=1e-5))

        algo = A.SGD(eta=0.4, k=4)
        ref = np.asarray(algo.round(p, algo.init(p, p.x0),
                                    jax.random.PRNGKey(7)).x)
        out = np.asarray(client_axis.sgd_round_client_sharded(
            mesh, p, p.x0, 0.4, jax.random.PRNGKey(7), k=4))
        checks['sgd_round'] = bool(np.allclose(ref, out, atol=1e-5))

        # indivisible client counts are refused, not silently mis-sharded
        try:
            client_axis.sharded_client_mean(
                mesh, jax.random.normal(jax.random.PRNGKey(5), (6, 4)))
            checks['divisibility_guard'] = False
        except ValueError:
            checks['divisibility_guard'] = True
        print(json.dumps(checks))
    """, devices=8)
    checks = json.loads(out.strip().splitlines()[-1])
    assert all(checks.values()), checks
