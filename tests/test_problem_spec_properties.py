"""Property tests: spec↔closure trajectory equivalence (hypothesis).

For randomly drawn problem constants (ζ, σ, seeds), a spec-built problem —
executed with the problem as an OPERAND — must reproduce the closure-built
trajectory BIT-EXACTLY: plain, under identity comm, and under QSGD. This is
the load-bearing guarantee of the ProblemSpec redesign (operand threading
and constant-baking must agree to the last bit, or grids and per-call runs
would silently diverge).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.comm import CommConfig
from repro.core import algorithms as A, runner
from repro.data import problems


def _bitexact(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(zeta=st.floats(0.0, 5.0), sigma=st.floats(0.0, 1.0),
       seed=st.integers(0, 50))
@settings(max_examples=8, deadline=None)
def test_quadratic_spec_closure_bitexact(zeta, sigma, seed):
    p = problems.quadratic_problem(
        jax.random.PRNGKey(seed), num_clients=5, dim=8, mu=0.1, beta=1.0,
        zeta=zeta, sigma=sigma, sigma_f=0.05)
    x0 = p.init_params(None)
    algo = A.SGD(eta=0.3, k=2, mu_avg=p.mu)
    r_spec = runner.run(algo, p.spec, x0, 5, jax.random.PRNGKey(seed + 1))
    r_clos = runner.run(algo, problems.without_spec(p), x0, 5,
                        jax.random.PRNGKey(seed + 1))
    _bitexact(r_spec.history, r_clos.history)


@given(zeta=st.floats(0.0, 3.0), seed=st.integers(0, 50),
       qsgd=st.booleans())
@settings(max_examples=8, deadline=None)
def test_quadratic_spec_closure_bitexact_comm(zeta, seed, qsgd):
    cfg = (CommConfig(compressor="qsgd", qsgd_bits=4) if qsgd
           else CommConfig())
    p = problems.quadratic_problem(
        jax.random.PRNGKey(seed), num_clients=5, dim=8, mu=0.1, beta=1.0,
        zeta=zeta, sigma=0.1, sigma_f=0.05)
    x0 = p.init_params(None)
    algo = A.SGD(eta=0.3, k=2, mu_avg=p.mu)
    r_spec = runner.run(algo, p.spec, x0, 4, jax.random.PRNGKey(seed + 1),
                        comm=cfg)
    r_clos = runner.run(algo, problems.without_spec(p), x0, 4,
                        jax.random.PRNGKey(seed + 1), comm=cfg)
    _bitexact(r_spec.history, r_clos.history)
    _bitexact(r_spec.bits_up, r_clos.bits_up)
    _bitexact(r_spec.bits_down, r_clos.bits_down)


@given(zeta=st.floats(0.0, 2.0), sigma=st.floats(0.0, 0.5),
       seed=st.integers(0, 50))
@settings(max_examples=6, deadline=None)
def test_perturbed_spec_closure_bitexact(zeta, sigma, seed):
    p = problems.general_convex_problem(
        jax.random.PRNGKey(seed), num_clients=4, dim=6, zeta=zeta,
        sigma=sigma)
    x0 = p.init_params(None)
    algo = A.FedAvg(eta=0.2, local_steps=2, inner_batch=2)
    r_spec = runner.run(algo, p.spec, x0, 4, jax.random.PRNGKey(seed + 1))
    r_clos = runner.run(algo, problems.without_spec(p), x0, 4,
                        jax.random.PRNGKey(seed + 1))
    # transcendental base: FMA contraction in the operand compile allows a
    # 1-ulp difference vs the constant-baked closure compile (see
    # tests/test_problem_spec.py); linear-algebra families stay bitwise
    np.testing.assert_allclose(np.asarray(r_spec.history),
                               np.asarray(r_clos.history), rtol=3e-7, atol=0)


@given(seed=st.integers(0, 20), l2=st.floats(0.01, 0.5))
@settings(max_examples=5, deadline=None)
def test_logreg_spec_closure_bitexact(seed, l2):
    rng = np.random.default_rng(seed)
    feats = rng.normal(size=(3, 20, 5)).astype(np.float32)
    labels = (rng.random((3, 20)) > 0.5).astype(np.float32)
    p = problems.logreg_problem(
        jax.random.PRNGKey(seed), features=jnp.asarray(feats),
        labels=jnp.asarray(labels), l2=l2, oracle_batch_frac=0.2)
    x0 = p.init_params(None)
    algo = A.SGD(eta=0.5, k=2, mu_avg=p.mu)
    r_spec = runner.run(algo, p.spec, x0, 4, jax.random.PRNGKey(seed + 1))
    r_clos = runner.run(algo, problems.without_spec(p), x0, 4,
                        jax.random.PRNGKey(seed + 1))
    _bitexact(r_spec.history, r_clos.history)
