"""Per-kernel sweeps: Pallas (interpret mode) vs pure-jnp oracles across
shapes and dtypes (the required kernel validation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.aggregate import ops as agg_ops
from repro.kernels.aggregate.aggregate import chain_aggregate, mean_over_clients
from repro.kernels.aggregate.ref import chain_aggregate_ref, mean_over_clients_ref
from repro.kernels.compress import ops as compress_ops
from repro.kernels.compress.compress import (
    qsgd_dequantize, weighted_mean_over_clients)
from repro.kernels.compress.ref import (
    qsgd_dequantize_ref, weighted_mean_over_clients_ref)
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


# --------------------------- aggregate --------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,d", [(1, 128), (4, 1000), (8, 4096), (16, 257)])
def test_chain_aggregate_sweep(s, d, dtype):
    key = jax.random.PRNGKey(s * 1000 + d)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (d,), dtype)
    g = jax.random.normal(ks[1], (s, d), dtype)
    ci = jax.random.normal(ks[2], (s, d), dtype)
    c = jax.random.normal(ks[3], (d,), dtype)
    w = jax.nn.softmax(jax.random.normal(ks[4], (s,)))
    out = chain_aggregate(x, g, ci, c, w, lr=0.37, interpret=True, block_d=256)
    ref = chain_aggregate_ref(x, g, ci, c, lr=0.37, weights=w)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


@given(
    c=st.integers(1, 6),
    dims=st.lists(st.integers(1, 9), min_size=1, max_size=3),
    bf16=st.booleans(),
)
@settings(max_examples=25, deadline=None)
def test_mean_over_clients_property(c, dims, bf16):
    dtype = jnp.bfloat16 if bf16 else jnp.float32
    t = jax.random.normal(jax.random.PRNGKey(c), (c, *dims), dtype)
    out = mean_over_clients(t, interpret=True, block_d=64)
    ref = mean_over_clients_ref(t)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2 if bf16 else 1e-6, atol=1e-6)


def test_aggregate_ops_dispatch():
    """CPU default path (ref) == forced-pallas path."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (300,))
    g = jax.random.normal(key, (4, 300))
    ci = jnp.zeros((4, 300))
    c = jnp.zeros((300,))
    a = agg_ops.chain_aggregate(x, g, ci, c, lr=0.1)
    b = agg_ops.chain_aggregate(x, g, ci, c, lr=0.1, force_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


def test_aggregate_is_fedavg_server_step():
    """lr=server_lr, g=client deltas, c_i=c=0 reproduces FedAvg's x update."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (64,))
    y = jax.random.normal(jax.random.PRNGKey(2), (5, 64))  # client finals
    deltas = x[None] - y
    out = chain_aggregate(x, deltas, jnp.zeros_like(deltas), jnp.zeros_like(x),
                          jnp.full((5,), 0.2), lr=1.0, interpret=True, block_d=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(jnp.mean(y, 0)),
                               rtol=1e-5, atol=1e-6)


# --------------------------- compress ----------------------------------------

@pytest.mark.parametrize("levels", [1.0, 15.0, 255.0])
@pytest.mark.parametrize("s,d", [(1, 128), (4, 1000), (8, 257)])
def test_qsgd_dequantize_sweep(s, d, levels):
    key = jax.random.PRNGKey(s * 100 + d)
    v = jax.random.normal(key, (s, d))
    u = jax.random.uniform(jax.random.PRNGKey(1), (s, d))
    norms = jnp.linalg.norm(v, axis=1)
    lv = jnp.float32(levels)
    out = qsgd_dequantize(v, u, norms, lv, interpret=True, block_d=256)
    ref = qsgd_dequantize_ref(v, u, norms, lv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)
    # dequantized values live on the sign·norm·{0..L}/L lattice
    lattice = np.round(np.abs(np.asarray(out)) / np.asarray(norms)[:, None]
                       * levels)
    np.testing.assert_allclose(
        np.abs(np.asarray(out)),
        lattice * np.asarray(norms)[:, None] / levels, rtol=1e-4, atol=1e-6)


def test_qsgd_zero_row_is_stable():
    v = jnp.zeros((2, 64)).at[1].set(1.0)
    u = jax.random.uniform(jax.random.PRNGKey(0), (2, 64))
    norms = jnp.linalg.norm(v, axis=1)
    out = qsgd_dequantize(v, u, norms, jnp.float32(15.0), interpret=True,
                          block_d=64)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_array_equal(np.asarray(out[0]), np.zeros(64))


@given(
    s=st.integers(1, 6),
    d=st.integers(1, 300),
    full=st.booleans(),
)
@settings(max_examples=25, deadline=None)
def test_weighted_mean_property(s, d, full):
    t = jax.random.normal(jax.random.PRNGKey(s + d), (s, d))
    w = (jnp.ones((s,)) if full
         else jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(1), (s,))) * s)
    out = weighted_mean_over_clients(t, w, interpret=True, block_d=64)
    ref = weighted_mean_over_clients_ref(t, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)
    want = np.einsum("s,sd->d", np.asarray(w), np.asarray(t)) / s
    np.testing.assert_allclose(np.asarray(ref), want, rtol=1e-5, atol=1e-5)


def test_weighted_mean_unit_weights_bitwise_equals_plain_mean():
    """The comm bit-exactness keystone: under full participation the masked
    aggregate IS the plain client mean, bit for bit (both dispatch paths)."""
    t = jax.random.normal(jax.random.PRNGKey(0), (8, 300))
    ones = jnp.ones((8,))
    assert bool(jnp.all(weighted_mean_over_clients_ref(t, ones)
                        == mean_over_clients_ref(t)))
    a = weighted_mean_over_clients(t, ones, interpret=True, block_d=128)
    b = mean_over_clients(t, interpret=True, block_d=128)
    assert bool(jnp.all(a == b))


def test_compress_ops_dispatch():
    """CPU default (ref) path == forced-pallas interpret path."""
    v = jax.random.normal(jax.random.PRNGKey(0), (4, 300))
    u = jax.random.uniform(jax.random.PRNGKey(1), (4, 300))
    norms = jnp.linalg.norm(v, axis=1)
    lv = jnp.float32(15.0)
    a = compress_ops.qsgd_dequantize(v, u, norms, lv)
    b = compress_ops.qsgd_dequantize(v, u, norms, lv, force_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-6)
    w = jnp.asarray([1.0, 0.0, 2.0, 1.0])
    c = compress_ops.weighted_mean_over_clients(v, w)
    d = compress_ops.weighted_mean_over_clients(v, w, force_pallas=True)
    np.testing.assert_allclose(np.asarray(c), np.asarray(d), rtol=1e-5,
                               atol=1e-6)


# --------------------------- flash attention --------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
@pytest.mark.parametrize("s,h,kv,d", [(256, 4, 2, 64), (128, 2, 2, 32),
                                      (256, 8, 1, 64)])
def test_flash_attention_sweep(s, h, kv, d, causal, window, dtype):
    key = jax.random.PRNGKey(s + h)
    q = jax.random.normal(key, (2, s, h, d), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, s, kv, d), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, s, kv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True, block_q=64, block_kv=64)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_flash_block_shape_independence():
    """Different BlockSpec tilings give identical results."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 256, 2, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 256, 2, 32))
    o1 = flash_attention(q, k, v, interpret=True, block_q=64, block_kv=64)
    o2 = flash_attention(q, k, v, interpret=True, block_q=128, block_kv=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5,
                               atol=1e-6)


def test_flash_matches_model_attend():
    """The Pallas kernel is the TPU version of models.layers attend()."""
    from repro.models.layers import attention as attn_lib

    s = 256
    q = jax.random.normal(jax.random.PRNGKey(0), (2, s, 4, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, s, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, s, 2, 32))
    pos = jnp.arange(s)
    bias = attn_lib.mask_bias(pos, pos, causal=True)
    model_out = attn_lib.attend(q, k, v, bias[None], scale=1 / 32**0.5)
    kern_out = flash_attention(q, k, v, causal=True, interpret=True,
                               block_q=64, block_kv=64)
    np.testing.assert_allclose(np.asarray(model_out), np.asarray(kern_out),
                               rtol=2e-4, atol=2e-5)


# --------------------------- SSD scan ---------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("l,h,p,g,n,chunk", [(64, 2, 8, 1, 8, 16),
                                             (128, 4, 16, 2, 16, 32)])
def test_ssd_scan_kernel_sweep(l, h, p, g, n, chunk, dtype):
    from repro.kernels.ssd_scan.ssd_scan import ssd_scan
    from repro.models.layers.ssm import ssd as ssd_ref

    key = jax.random.PRNGKey(l + h)
    b = 2
    x = jax.random.normal(key, (b, l, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (b, l, h))).astype(jnp.float32)
    a = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (h,)) * 0.3)
    bi = (jax.random.normal(jax.random.PRNGKey(3), (b, l, g, n)) * 0.5).astype(dtype)
    ci = (jax.random.normal(jax.random.PRNGKey(4), (b, l, g, n)) * 0.5).astype(dtype)
    got = ssd_scan(x, dt, a, bi, ci, chunk=chunk, interpret=True)
    want, _ = ssd_ref(x, dt, a, bi, ci, chunk=chunk)
    tol = 2e-3 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)
