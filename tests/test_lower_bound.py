"""App. G lower-bound construction: zero-chain property, curvature bounds,
gap formulas, and the empirical floor for zero-respecting algorithms."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import algorithms as A, lower_bound as lb, runner


@pytest.fixture(scope="module")
def inst():
    problem, instance = lb.make_lower_bound_problem(
        dim=32, beta=1.0, mu=0.01, zeta_hat=1.0)
    return problem, instance


def test_curvature_bounds(inst):
    """F1, F2 are μ-strongly convex and β-smooth (App. G.1, ℓ₂ ≤ (β−μ)/4)."""
    problem, instance = inst
    for f in (instance.f1, instance.f2, instance.f):
        h = jax.hessian(f)(jnp.zeros(instance.dim))
        eigs = jnp.linalg.eigvalsh(h)
        assert float(eigs.min()) >= instance.mu - 1e-6
        assert float(eigs.max()) <= 1.0 + 1e-6  # beta


def test_zero_chain_property(inst):
    """Eqs. 276–277: from even support only ∇F1 unlocks the next coordinate;
    from odd support only ∇F2 does."""
    _, it = inst
    d = it.dim
    for i in range(0, 6, 2):  # even number of unlocked coords
        x = jnp.zeros(d).at[:i].set(1.0)
        g1 = jax.grad(it.f1)(x)
        g2 = jax.grad(it.f2)(x)
        assert lb.max_unlocked_coordinate(g1) <= i + 1
        assert lb.max_unlocked_coordinate(g2) <= i
    for i in range(1, 7, 2):  # odd number unlocked
        x = jnp.zeros(d).at[:i].set(1.0)
        g1 = jax.grad(it.f1)(x)
        g2 = jax.grad(it.f2)(x)
        assert lb.max_unlocked_coordinate(g1) <= i
        assert lb.max_unlocked_coordinate(g2) <= i + 1


def test_initial_gap_formula(inst):
    problem, it = inst
    gap = problem.delta(jnp.zeros(it.dim))
    assert gap <= float(it.initial_gap_ub()) * 1.01
    assert gap >= 0.5 * float(it.initial_gap_ub())  # the bound is tight-ish


def test_x_star_geometric(inst):
    problem, it = inst
    xs = problem.x_star
    # known geometric form (x*_j ∝ q^j) away from the boundary
    ratio = xs[2:10] / xs[1:9]
    assert float(jnp.std(ratio)) < 0.05
    assert float(jnp.mean(ratio)) == pytest.approx(it.q, rel=0.1)


def test_algorithms_hit_the_floor(inst):
    """Any distributed zero-respecting algorithm unlocks ≤ R coordinates in R
    rounds (Lemma G.4) ⇒ suboptimality ≥ the analytic floor."""
    problem, it = inst
    x0 = jnp.zeros(it.dim)
    rounds = 8
    for algo in [A.SGD(eta=1.5, k=1, output_mode="last"),
                 A.FedAvg(eta=1.0, local_steps=4, inner_batch=1)]:
        res = runner.run(algo, problem, x0, rounds, jax.random.PRNGKey(0))
        # support grew at most 1 per round (+1 slack for averaging boundary)
        unlocked = lb.max_unlocked_coordinate(res.state.x, tol=1e-9)
        assert unlocked <= rounds + 1
        floor = it.suboptimality_lb(rounds)
        assert float(problem.suboptimality(res.state.x)) >= 0.5 * float(floor)


def test_floor_decays_like_q2R(inst):
    _, it = inst
    l4, l8 = it.suboptimality_lb(4), it.suboptimality_lb(8)
    assert l8 == pytest.approx(l4 * it.q ** 8, rel=1e-6)
