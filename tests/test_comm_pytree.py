"""Pytree comm: leaf-wise bits accounting, vision-family equivalence, and
the comm × problems sweep axis.

The PR-4 guarantees on top of the PR-2 comm contract:

(a) bits accounting over MULTI-LEAF parameter pytrees equals the sum of
    per-leaf closed forms (QSGD bills one norm per leaf; top-k/rand-k keep
    k coordinates per leaf with per-leaf index widths) — checked both
    against the helper closed forms and against the bits an actual run
    bills;
(b) identity compression + full participation on the vision family is
    bit-exact with the plain executors AND with the legacy
    ``make_vision_problem`` closure path (``problems.without_spec``);
(c) ``run_sweep(problems=..., comm=...)`` compiles each executor exactly
    once across a ζ×σ problem grid with QSGD + partial participation
    (``TRACE_COUNTS``-asserted) and every cell is reproducible per-call via
    the documented ``fold = p·S + s`` mask schedule;
(d) error-feedback residual tables mirror the parameter pytree leaf-for-leaf
    and masked-out clients keep their residuals.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommConfig, CommParams, uplink_bits_per_client
from repro.comm import config as comm_cfg
from repro.comm.compressors import COMP_IDS
from repro.core import algorithms as A, chain, runner, sweep
from repro.data import problems
from repro.data.vision_problem import (
    make_vision_problem, vision_accuracy, vision_spec,
)

N_CLIENTS = 4


@pytest.fixture(scope="module")
def vspec():
    return vision_spec(
        jax.random.PRNGKey(0), num_clients=N_CLIENTS,
        num_classes=2 * N_CLIENTS, per_class=16, side=6, hidden=8, batch=4)


@pytest.fixture(scope="module")
def leaf_d(vspec):
    return comm_cfg.leaf_dims(vspec.x0)


# -------------------- (a) leaf-wise bits closed forms -----------------------

def _py_closed_form(comp, d, bits=4, k=2):
    """The per-leaf closed forms, recomputed independently in Python."""
    if comp == "identity":
        return 32.0 * d
    if comp == "qsgd":
        return 32.0 + d * (bits + 1.0)
    idx = float(max(1, math.ceil(math.log2(d)))) if d > 1 else 1.0
    return k * (32.0 + idx)


@pytest.mark.parametrize("comp", ["identity", "qsgd", "topk", "randk"])
def test_tree_bits_equal_sum_of_leaf_closed_forms(comp, leaf_d):
    params = CommParams(
        comp_id=jnp.asarray(COMP_IDS[comp], jnp.int32),
        qsgd_bits=jnp.asarray(4.0, jnp.float32),
        spars_k=jnp.asarray(2, jnp.int32))
    tree_bits = float(
        comm_cfg.uplink_bits_per_client_tree(params, leaf_d))
    expect = sum(_py_closed_form(comp, d) for d in leaf_d)
    assert tree_bits == expect
    # ...and the single-leaf helper agrees per leaf
    per_leaf = [float(uplink_bits_per_client(params, d)) for d in leaf_d]
    assert tree_bits == sum(per_leaf)


def test_billed_bits_match_leaf_sum_on_vision(vspec, leaf_d):
    """The bits an actual pytree run bills equal N·Σ_leaf closed_form."""
    algo = A.SGD(eta=0.1, k=2, output_mode="last")
    total_d = sum(leaf_d)
    cases = [
        (CommConfig(), sum(_py_closed_form("identity", d) for d in leaf_d)),
        (CommConfig(compressor="qsgd", qsgd_bits=4),
         sum(_py_closed_form("qsgd", d) for d in leaf_d)),
        (CommConfig(compressor="randk", spars_k=2, participation=0.5),
         sum(_py_closed_form("randk", d) for d in leaf_d)),
        (CommConfig(compressor="topk", spars_k=2),
         sum(_py_closed_form("topk", d) for d in leaf_d)),
    ]
    for cfg, per_client in cases:
        res = runner.run(algo, vspec, vspec.x0, 3, jax.random.PRNGKey(0),
                         comm=cfg)
        s_r = cfg.clients_per_round(N_CLIENTS)
        np.testing.assert_array_equal(
            np.asarray(res.bits_up), np.full(3, float(s_r * per_client)),
            err_msg=cfg.name)
        np.testing.assert_array_equal(
            np.asarray(res.bits_down),
            np.full(3, float(s_r * 32 * total_d)), err_msg=cfg.name)
        assert cfg.uplink_bits(vspec.x0) == per_client


def test_scaffold_vision_bills_two_pytrees_each_way(vspec, leaf_d):
    res = runner.run(A.Scaffold(eta=0.1, local_steps=2, inner_batch=2),
                     vspec, vspec.x0, 2, jax.random.PRNGKey(0),
                     comm=CommConfig())
    total = float(N_CLIENTS * 2 * 32 * sum(leaf_d))
    np.testing.assert_array_equal(np.asarray(res.bits_up), np.full(2, total))
    np.testing.assert_array_equal(np.asarray(res.bits_down),
                                  np.full(2, total))


def test_chain_selection_bits_use_total_pytree_dim(vspec, leaf_d):
    ch = chain.fedchain(
        A.FedAvg(eta=0.1, local_steps=2, inner_batch=2),
        A.SGD(eta=0.1, k=2, output_mode="last"), selection_k=2,
        name="vis-bits-chain")
    res = ch.run(vspec, vspec.x0, 8, jax.random.PRNGKey(0),
                 comm=CommConfig())
    sel = res.switch_rounds[0] - 1
    assert np.asarray(res.bits_up)[sel] == 2 * 32 * N_CLIENTS
    assert np.asarray(res.bits_down)[sel] == (
        2 * 32 * sum(leaf_d) * N_CLIENTS)


# -------------------- (b) vision identity bit-exactness ---------------------

@pytest.mark.parametrize("name", ["sgd", "fedavg", "scaffold"])
def test_vision_identity_full_participation_bitexact(vspec, name):
    algo = {
        "sgd": A.SGD(eta=0.1, k=2, output_mode="last"),
        "fedavg": A.FedAvg(eta=0.1, local_steps=2, inner_batch=2),
        "scaffold": A.Scaffold(eta=0.1, local_steps=2, inner_batch=2),
    }[name]
    plain = runner.run(algo, vspec, vspec.x0, 6, jax.random.PRNGKey(3))
    comm = runner.run(algo, vspec, vspec.x0, 6, jax.random.PRNGKey(3),
                      comm=CommConfig())
    assert np.array_equal(np.asarray(plain.history), np.asarray(comm.history))
    for a, b in zip(jax.tree.leaves(plain.x_hat), jax.tree.leaves(comm.x_hat)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_vision_spec_bitexact_vs_legacy_closure_path():
    """The spec operand path reproduces the legacy ``make_vision_problem``
    closure path bit-for-bit (identity comm included)."""
    problem, accuracy, init = make_vision_problem(
        jax.random.PRNGKey(0), num_clients=N_CLIENTS,
        num_classes=2 * N_CLIENTS, per_class=16, side=6, hidden=8, batch=4)
    legacy = problems.without_spec(problem)
    x0 = problem.spec.x0
    algo = A.SGD(eta=0.1, k=2, output_mode="last")
    r_spec = runner.run(algo, problem.spec, x0, 6, jax.random.PRNGKey(3))
    r_legacy = runner.run(algo, legacy, x0, 6, jax.random.PRNGKey(3))
    assert np.array_equal(np.asarray(r_spec.history),
                          np.asarray(r_legacy.history))
    r_comm = runner.run(algo, legacy, x0, 6, jax.random.PRNGKey(3),
                        comm=CommConfig())
    assert np.array_equal(np.asarray(r_spec.history),
                          np.asarray(r_comm.history))
    assert 0.0 <= float(accuracy(r_spec.x_hat)) <= 1.0


# -------------------- (c) comm × problems axis ------------------------------

def test_comm_problems_axis_single_compile_and_per_cell_repro():
    specs = [problems.quadratic_spec(
        jax.random.PRNGKey(0), num_clients=8, dim=16, mu=0.1, beta=1.0,
        zeta=z, sigma=s, sigma_f=0.05)
        for z in (0.2, 1.0) for s in (0.0, 0.2)]
    x0 = specs[0].x0
    cfg = CommConfig(compressor="qsgd", qsgd_bits=4, participation=0.5)
    algo = A.SGD(eta=0.4, k=4, mu_avg=0.1, name="cxp-sgd")
    seeds, etas = (0, 1), (0.3, 0.5)
    with runner.assert_no_retrace(
            traced=("sweep-comm-probs/cxp-sgd", "runner-comm/cxp-sgd"),
            what="cold comm problems-axis grid"):
        res = sweep.run_sweep(algo, None, x0, 8, seeds=seeds, etas=etas,
                              problems=specs, comm=cfg)
    assert res.bits_up.shape == (4, 2, 2, 8)
    assert res.problems == tuple(s.name for s in specs)
    # switching compressor / participation must not add a compile
    with runner.assert_no_retrace(what="compressor/participation switch"):
        for other in [CommConfig(), CommConfig(compressor="randk", spars_k=4)]:
            sweep.run_sweep(algo, None, x0, 8, seeds=seeds, etas=etas,
                            problems=specs, comm=other)
    # per-cell reproducibility: cell (p, s) uses mask fold p·S + s
    pi, si, ei = 3, 1, 0
    rr = runner.run(algo, specs[pi], x0, 8, jax.random.PRNGKey(seeds[si]),
                    eta=etas[ei], comm=cfg,
                    comm_masks=cfg.round_masks(8, 8,
                                               fold=pi * len(seeds) + si))
    np.testing.assert_array_equal(np.asarray(res.bits_up[pi, si, ei]),
                                  np.asarray(rr.bits_up))
    np.testing.assert_allclose(np.asarray(res.history[pi, si, ei]),
                               np.asarray(rr.history), rtol=2e-4, atol=1e-6)


def test_vision_comm_problems_axis(vspec):
    """Table 3's heterogeneity grid rides the comm sweep in one compile."""
    specs = [vision_spec(
        jax.random.PRNGKey(0), num_clients=N_CLIENTS,
        num_classes=2 * N_CLIENTS, per_class=16, side=6, hidden=8, batch=4,
        homogeneous_frac=f) for f in (0.25, 0.75)]
    algo = A.SGD(eta=0.2, k=2, output_mode="last", name="cxp-vis-sgd")
    cfg = CommConfig(compressor="qsgd", qsgd_bits=4, participation=0.5)
    with runner.assert_no_retrace(
            traced=("sweep-comm-probs/cxp-vis-sgd", "runner-comm/cxp-vis-sgd"),
            what="cold vision comm problems-axis grid"):
        res = sweep.run_sweep(algo, None, None, 5, seeds=(0, 1),
                              etas=(0.1, 0.2), problems=specs, comm=cfg)
    h = np.asarray(res.history)
    assert h.shape == (2, 2, 2, 5) and np.isfinite(h).all()
    acc = vision_accuracy(specs[0])(
        jax.tree.map(lambda l: l[0, 0, 0], res.x_hat))
    assert 0.0 <= float(acc) <= 1.0


# -------------------- (d) error-feedback residual pytrees -------------------

def test_ef_residual_mirrors_param_pytree(vspec):
    cfg = CommConfig(compressor="topk", spars_k=2, error_feedback=True,
                     participation=0.5)
    res = runner.run(A.SGD(eta=0.1, k=2, output_mode="last"), vspec,
                     vspec.x0, 4, jax.random.PRNGKey(0), comm=cfg)
    residual = res.state.comm.residual
    assert (jax.tree_util.tree_structure(residual)
            == jax.tree_util.tree_structure(vspec.x0))
    for r, p in zip(jax.tree.leaves(residual), jax.tree.leaves(vspec.x0)):
        assert r.shape == (N_CLIENTS,) + p.shape
    # EF residuals are nonzero once a lossy compressor ran
    assert any(float(jnp.abs(r).sum()) > 0 for r in jax.tree.leaves(residual))
    assert np.isfinite(np.asarray(res.history)).all()


def test_spars_k_validated_against_smallest_leaf(vspec):
    # smallest vision leaf is the hidden bias (8 entries here)
    small = min(comm_cfg.leaf_dims(vspec.x0))
    with pytest.raises(ValueError, match="exceeds the parameter dimension"):
        CommConfig(compressor="topk", spars_k=small + 1).init_state(
            N_CLIENTS, vspec.x0)


# -------------------- hypothesis: leaf-sum property -------------------------

def test_hypothesis_leaf_partition_bits_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(dims=st.lists(st.integers(1, 4096), min_size=1, max_size=6),
           comp=st.sampled_from(["identity", "qsgd", "topk", "randk"]),
           bits=st.integers(1, 8), k=st.integers(1, 8))
    @settings(max_examples=40, deadline=None)
    def check(dims, comp, bits, k):
        params = CommParams(
            comp_id=jnp.asarray(COMP_IDS[comp], jnp.int32),
            qsgd_bits=jnp.asarray(float(bits), jnp.float32),
            spars_k=jnp.asarray(k, jnp.int32))
        tree_bits = float(
            comm_cfg.uplink_bits_per_client_tree(params, tuple(dims)))
        expect = sum(
            _py_closed_form(comp, d, bits=bits, k=k) for d in dims)
        assert tree_bits == pytest.approx(expect, rel=1e-6)
        # a single-leaf "pytree" degenerates to the flat closed form
        flat = float(uplink_bits_per_client(params, dims[0]))
        single = float(
            comm_cfg.uplink_bits_per_client_tree(params, (dims[0],)))
        assert flat == single

    check()
